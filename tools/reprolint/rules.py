"""The repo-specific reprolint rules (R001–R007).

Each rule encodes one measurement invariant from ARCHITECTURE.md /
docs/contracts.md. They are deliberately conservative static
approximations: they gate on the structural signature of the contract
(a store class that wraps `self.inner`, a class that owns
`_journal_append`, a function under tracing) so unrelated code is never
flagged, and they analyze in source-line order, which matches how the
contracts are written ("journal BEFORE apply", "bill THROUGH the model").
"""
from __future__ import annotations

import ast
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Set

from tools.reprolint.core import Finding, Rule, rule

# ---------------------------------------------------------------------------
# shared helpers


def _call_name(call: ast.Call) -> str:
    """Rightmost identifier of the thing being called ('' if exotic)."""
    fn = call.func
    if isinstance(fn, ast.Name):
        return fn.id
    if isinstance(fn, ast.Attribute):
        return fn.attr
    return ""


def _value_chain(node: ast.expr) -> str:
    """Dotted prefix of an attribute access, e.g. 'np.random' for
    np.random.default_rng — '' when the base isn't a plain name chain."""
    parts: List[str] = []
    cur = node
    while isinstance(cur, ast.Attribute):
        parts.append(cur.attr)
        cur = cur.value
    if isinstance(cur, ast.Name):
        parts.append(cur.id)
        return ".".join(reversed(parts))
    return ""


def _in_parts(path: str, name: str) -> bool:
    return name in Path(path).parts


# ---------------------------------------------------------------------------
# R001 — conservation spine


@rule
class R001ConservationSpine(Rule):
    """Store decorators must forward fetch/charge/note_write to `inner`.

    A class that wraps another store (assigns ``self.inner``) and overrides
    one of the spine methods must keep the conservation spine intact: the
    override has to reach the inner store through the booking helpers
    (`fetch_mirroring_inner` / `charge_inner_reads` / `note_inner_writes`),
    a direct ``self.inner.<method>(...)`` call, or the versioned
    ``self._mirrored(...)`` delegator — otherwise reads or writes silently
    vanish from the per-layer counters (the exact bug PR 4 and PR 8 fixed
    by hand).
    """

    rule_id = "R001"
    name = "conservation-spine"
    description = ("wrapping stores must forward fetch/charge/note_write "
                   "to the inner store via the booking helpers")

    FORWARDERS = {
        "fetch": {"fetch_mirroring_inner"},
        "charge": {"charge_inner_reads"},
        "note_write": {"note_inner_writes"},
    }

    def check(self, tree: ast.Module, src: str) -> Iterator[Finding]:
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            if not self._wraps_inner(cls):
                continue
            for meth in cls.body:
                if (isinstance(meth, ast.FunctionDef)
                        and meth.name in self.FORWARDERS
                        and not self._forwards(meth)):
                    yield self.finding(
                        meth,
                        f"{cls.name}.{meth.name} wraps an inner store but "
                        f"never forwards to it (expected one of "
                        f"{sorted(self.FORWARDERS[meth.name])}, "
                        f"self.inner.{meth.name}(...), or "
                        f"self._mirrored(...))")

    @staticmethod
    def _wraps_inner(cls: ast.ClassDef) -> bool:
        for node in ast.walk(cls):
            if isinstance(node, ast.Assign):
                for tgt in node.targets:
                    if (isinstance(tgt, ast.Attribute)
                            and tgt.attr == "inner"
                            and isinstance(tgt.value, ast.Name)
                            and tgt.value.id == "self"):
                        return True
        return False

    def _forwards(self, meth: ast.FunctionDef) -> bool:
        allowed = self.FORWARDERS[meth.name]
        for node in ast.walk(meth):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if name in allowed or name == "_mirrored":
                return True
            # self.inner.<method>(...) — direct delegation
            if (name == meth.name
                    and isinstance(node.func, ast.Attribute)
                    and _value_chain(node.func.value) == "self.inner"):
                return True
        return False


# ---------------------------------------------------------------------------
# R002 — journal before apply


@rule
class R002JournalBeforeApply(Rule):
    """Write-ahead means AHEAD: in any class owning `_journal_append`,
    the mutating methods (insert/delete/flush/compact) must emit their
    journal record before touching recoverable state, and no method may
    mutate recoverable state above its first `_journal_append` call.
    Otherwise a crash between apply and append loses the operation and
    `recover()` silently diverges from the live index.
    """

    rule_id = "R002"
    name = "journal-before-apply"
    description = ("mutating MutableIndex methods must call _journal_append "
                   "before touching delta/tombstone/free-list state")

    MUTATORS = {"insert", "delete", "flush", "compact"}
    STATE_ATTRS = {
        "delta", "deleted", "pending_tombstones", "dirty_pages",
        "append_pages", "free_pages", "graph", "vectors", "next_vid",
        "n_disk",
    }
    MUTATING_CALLS = {
        "add", "remove", "discard", "insert", "append", "extend",
        "clear", "pop", "update", "drain", "load", "setdefault",
    }

    def check(self, tree: ast.Module, src: str) -> Iterator[Finding]:
        for cls in ast.walk(tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            owns_journal = any(
                isinstance(m, ast.FunctionDef) and m.name == "_journal_append"
                for m in cls.body)
            if not owns_journal:
                continue
            for meth in cls.body:
                if (not isinstance(meth, ast.FunctionDef)
                        or meth.name == "_journal_append"):
                    continue
                journal_line = self._first_journal_line(meth)
                if meth.name in self.MUTATORS and journal_line is None:
                    yield self.finding(
                        meth,
                        f"{cls.name}.{meth.name} mutates the index but "
                        f"never calls self._journal_append")
                    continue
                if journal_line is None:
                    continue
                bad = self._mutation_before(meth, journal_line)
                if bad is not None:
                    yield self.finding(
                        bad,
                        f"{cls.name}.{meth.name} touches recoverable state "
                        f"(line {bad.lineno}) before the journal append on "
                        f"line {journal_line}")

    @staticmethod
    def _first_journal_line(meth: ast.FunctionDef) -> Optional[int]:
        best: Optional[int] = None
        for node in ast.walk(meth):
            if (isinstance(node, ast.Call)
                    and _call_name(node) == "_journal_append"
                    and isinstance(node.func, ast.Attribute)
                    and _value_chain(node.func.value) == "self"):
                if best is None or node.lineno < best:
                    best = node.lineno
        return best

    def _mutation_before(self, meth: ast.FunctionDef,
                         journal_line: int) -> Optional[ast.AST]:
        for node in ast.walk(meth):
            line = getattr(node, "lineno", None)
            if line is None or line >= journal_line:
                continue
            if isinstance(node, (ast.Assign, ast.AugAssign)):
                targets = (node.targets if isinstance(node, ast.Assign)
                           else [node.target])
                for tgt in targets:
                    if self._is_state_target(tgt):
                        return node
            elif isinstance(node, ast.Call):
                name = _call_name(node)
                if (name in self.MUTATING_CALLS
                        and isinstance(node.func, ast.Attribute)):
                    chain = _value_chain(node.func.value)
                    attr = chain.split(".")[1] if chain.startswith(
                        "self.") and chain.count(".") >= 1 else ""
                    if attr in self.STATE_ATTRS:
                        return node
        return None

    def _is_state_target(self, tgt: ast.expr) -> bool:
        if isinstance(tgt, ast.Tuple):
            return any(self._is_state_target(e) for e in tgt.elts)
        if isinstance(tgt, ast.Subscript):
            tgt = tgt.value
        return (isinstance(tgt, ast.Attribute)
                and isinstance(tgt.value, ast.Name)
                and tgt.value.id == "self"
                and tgt.attr in self.STATE_ATTRS)


# ---------------------------------------------------------------------------
# R003 — clock discipline


def _clockish(name: str) -> bool:
    return name.endswith("_us") or name.endswith("_free")


@rule
class R003ClockDiscipline(Rule):
    """Outside `serving/`, device-time fields (``*_us`` / ``*_free``
    attributes) may only be charged through the device model
    (``*_service_us`` / ``concurrent_latency_us``), reset to zero, or
    re-aggregated from other already-billed clock values. A raw float
    landing in a clock field is unpriced time: the complexity model can
    no longer explain the latency it produces.
    """

    rule_id = "R003"
    name = "clock-discipline"
    description = ("device time is only billed through SSDModel "
                   "*_service_us / window APIs outside serving/")

    def check(self, tree: ast.Module, src: str) -> Iterator[Finding]:
        if _in_parts(self.path, "serving"):
            return
        for node in ast.walk(tree):
            if isinstance(node, ast.Assign):
                targets, rhs = node.targets, node.value
            elif isinstance(node, ast.AugAssign):
                targets, rhs = [node.target], node.value
            else:
                continue
            for tgt in self._flat(targets):
                if (isinstance(tgt, ast.Attribute) and _clockish(tgt.attr)
                        # measured_* fields are host wall-clock measurements
                        # stamped NEXT TO the modeled clock (PR 6) — they are
                        # the cross-check, not billed device time
                        and not tgt.attr.startswith("measured_")
                        and not self._billed(rhs)):
                    yield self.finding(
                        node,
                        f"clock field .{tgt.attr} assigned from a value "
                        f"with no *_service_us/concurrent_latency_us call "
                        f"(bill through the device model, or zero-reset)")

    @staticmethod
    def _flat(targets: List[ast.expr]) -> Iterator[ast.expr]:
        for t in targets:
            if isinstance(t, ast.Tuple):
                yield from t.elts
            else:
                yield t

    @staticmethod
    def _billed(rhs: ast.expr) -> bool:
        if (isinstance(rhs, ast.Constant)
                and isinstance(rhs.value, (int, float)) and not rhs.value):
            return True          # zero reset
        for node in ast.walk(rhs):
            if isinstance(node, ast.Call):
                name = _call_name(node)
                if (name.endswith("_service_us")
                        or name == "concurrent_latency_us"):
                    return True
        # re-aggregation: combining already-billed clock values is fine as
        # long as no fresh nonzero literal sneaks in
        has_clock_ref = False
        for node in ast.walk(rhs):
            if (isinstance(node, ast.Constant)
                    and isinstance(node.value, (int, float))
                    and not isinstance(node.value, bool) and node.value):
                return False
            if isinstance(node, ast.Attribute) and _clockish(node.attr):
                has_clock_ref = True
            elif isinstance(node, ast.Name) and _clockish(node.id):
                has_clock_ref = True
        return has_clock_ref


# ---------------------------------------------------------------------------
# R004 — kernel purity


@rule
class R004KernelPurity(Rule):
    """Traced regions (jit-decorated functions and Pallas kernel bodies in
    `src/repro/kernels/` + `core/search_kernel.py`) must stay pure: no
    wall-clock reads, no host RNG, no unseeded generators, and no host
    concretization (`.item()`, `float(tracer)`) — each silently breaks
    either determinism or the compiled trace.
    """

    rule_id = "R004"
    name = "kernel-purity"
    description = ("no time.*, random.*, unseeded default_rng, or host "
                   ".item()/float() concretization in traced kernel code")

    def check(self, tree: ast.Module, src: str) -> Iterator[Finding]:
        if not (_in_parts(self.path, "kernels")
                or self.path.endswith("search_kernel.py")):
            return
        kernel_names = self._pallas_kernel_names(tree)
        for fn in ast.walk(tree):
            if not isinstance(fn, ast.FunctionDef):
                continue
            if not (fn.name in kernel_names or self._is_jitted(fn)):
                continue
            yield from self._check_traced(fn)

    @staticmethod
    def _pallas_kernel_names(tree: ast.Module) -> Set[str]:
        names: Set[str] = set()
        for node in ast.walk(tree):
            if (isinstance(node, ast.Call)
                    and _call_name(node).endswith("pallas_call")
                    and node.args and isinstance(node.args[0], ast.Name)):
                names.add(node.args[0].id)
        return names

    @staticmethod
    def _is_jitted(fn: ast.FunctionDef) -> bool:
        return any("jit" in ast.unparse(d) for d in fn.decorator_list)

    def _check_traced(self, fn: ast.FunctionDef) -> Iterator[Finding]:
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            chain = (_value_chain(node.func)
                     if isinstance(node.func, ast.Attribute) else "")
            name = _call_name(node)
            if chain.startswith("time."):
                yield self.finding(node, f"wall clock ({chain}) inside "
                                         f"traced function {fn.name}")
            elif (chain.startswith(("random.", "np.random.",
                                    "numpy.random."))):
                # a seeded default_rng(seed) is host-side setup and allowed;
                # everything else is nondeterminism under the trace
                if not (name == "default_rng"
                        and (node.args or node.keywords)):
                    yield self.finding(
                        node, f"host RNG ({chain}) inside traced "
                              f"function {fn.name}")
            elif name == "item" and isinstance(node.func, ast.Attribute):
                yield self.finding(
                    node, f".item() concretizes a device value inside "
                          f"traced function {fn.name}")
            elif (isinstance(node.func, ast.Name)
                  and node.func.id in {"float", "int"}
                  and node.args
                  and not isinstance(node.args[0], ast.Constant)):
                yield self.finding(
                    node, f"builtin {node.func.id}() forces host "
                          f"concretization inside traced function {fn.name}")


# ---------------------------------------------------------------------------
# R005 — report-schema stability


@rule
class R005ReportSchema(Rule):
    """`row()` / `*_columns()` implementations must build their dicts from
    static keys: string constants, or f-string keys produced inside
    deterministically ordered loops (`sorted(...)`, `enumerate(...)`,
    `range(...)`, literal tuples). A key pulled from runtime data in
    arbitrary order breaks the schema-prefix guarantee FleetReport's
    consumers (CSV writers, the replay harness) rely on.
    """

    rule_id = "R005"
    name = "report-schema"
    description = ("row()/*_columns() must use constant keys or f-string "
                   "keys inside deterministically ordered loops")

    def check(self, tree: ast.Module, src: str) -> Iterator[Finding]:
        for fn in ast.walk(tree):
            if not isinstance(fn, ast.FunctionDef):
                continue
            if not (fn.name == "row" or fn.name.endswith("_columns")):
                continue
            parents = self._parent_map(fn)
            for node in ast.walk(fn):
                if isinstance(node, ast.Dict):
                    for key in node.keys:
                        if key is None:      # **spread: checked at source
                            continue
                        yield from self._check_key(fn, key, parents)
                elif (isinstance(node, (ast.Assign, ast.AugAssign))):
                    tgts = (node.targets if isinstance(node, ast.Assign)
                            else [node.target])
                    for tgt in tgts:
                        if isinstance(tgt, ast.Subscript):
                            yield from self._check_key(
                                fn, tgt.slice, parents)

    def _check_key(self, fn: ast.FunctionDef, key: ast.expr,
                   parents: Dict[ast.AST, ast.AST]) -> Iterator[Finding]:
        if isinstance(key, ast.Constant):
            return
        if isinstance(key, ast.JoinedStr):
            loop = self._nondeterministic_loop(key, fn, parents)
            if loop is not None:
                yield self.finding(
                    key,
                    f"{fn.name} builds an f-string column key inside a "
                    f"loop whose order isn't pinned (line {loop.lineno}) "
                    f"— iterate sorted(...)/enumerate(...)/a literal")
            return
        yield self.finding(
            key, f"{fn.name} uses a dynamic column key "
                 f"({ast.unparse(key)}); report schemas need static keys")

    @staticmethod
    def _parent_map(fn: ast.FunctionDef) -> Dict[ast.AST, ast.AST]:
        parents: Dict[ast.AST, ast.AST] = {}
        for node in ast.walk(fn):
            for child in ast.iter_child_nodes(node):
                parents[child] = node
        return parents

    def _nondeterministic_loop(
            self, key: ast.expr, fn: ast.FunctionDef,
            parents: Dict[ast.AST, ast.AST]) -> Optional[ast.AST]:
        node: ast.AST = key
        while node is not fn:
            node = parents.get(node, fn)
            if isinstance(node, (ast.For, ast.AsyncFor)):
                if not self._det_iter(node.iter):
                    return node
            elif isinstance(node, (ast.DictComp, ast.ListComp,
                                   ast.SetComp, ast.GeneratorExp)):
                for gen in node.generators:
                    if not self._det_iter(gen.iter):
                        return node
        return None

    def _det_iter(self, it: ast.expr) -> bool:
        if isinstance(it, (ast.Tuple, ast.List)):
            return True
        if isinstance(it, ast.Call):
            name = _call_name(it)
            if name in {"sorted", "range"}:
                return True
            if name in {"enumerate", "zip"}:
                return all(self._det_iter(a) for a in it.args)
        return False


# ---------------------------------------------------------------------------
# R006 — seeded RNG


@rule
class R006SeededRng(Rule):
    """Benchmarks and tests must build RNGs from explicit seeds
    (`np.random.default_rng(seed)`): the global legacy generators and
    zero-arg `default_rng()` make every run unrepeatable, which turns a
    perf regression into an unanswerable "was it noise?".
    """

    rule_id = "R006"
    name = "seeded-rng"
    description = ("benchmarks/tests construct RNGs from explicit seeds — "
                   "no unseeded default_rng() or global legacy RNG calls")

    NP_LEGACY = {
        "seed", "rand", "randn", "randint", "random", "random_sample",
        "choice", "shuffle", "permutation", "normal", "uniform",
        "standard_normal",
    }
    PY_RANDOM = {
        "random", "randint", "choice", "shuffle", "sample", "seed",
        "uniform", "gauss", "randrange",
    }

    def check(self, tree: ast.Module, src: str) -> Iterator[Finding]:
        if not (_in_parts(self.path, "tests")
                or _in_parts(self.path, "benchmarks")):
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            if not isinstance(node.func, ast.Attribute):
                continue
            chain = _value_chain(node.func.value)
            name = node.func.attr
            if name == "default_rng" and chain in {"np.random",
                                                   "numpy.random"}:
                if not node.args and not node.keywords:
                    yield self.finding(
                        node, "unseeded default_rng() — pass an explicit "
                              "seed so the run is repeatable")
            elif chain in {"np.random", "numpy.random"}:
                if name in self.NP_LEGACY:
                    yield self.finding(
                        node, f"global legacy RNG {chain}.{name}(...) — "
                              f"use np.random.default_rng(seed)")
            elif chain == "random" and name in self.PY_RANDOM:
                yield self.finding(
                    node, f"stdlib random.{name}(...) uses hidden global "
                          f"state — use np.random.default_rng(seed)")


# ---------------------------------------------------------------------------
# R007 — span clock discipline


@rule
class R007SpanClockDiscipline(Rule):
    """Observability is a *mirror* of the priced clocks, never a source:
    inside `src/repro/obs/`, every ``*_us`` keyword argument (Span fields,
    ``tracer.span(t0_us=..., dur_us=...)``, summary rollups) must derive
    from already-billed clock values or the device model's
    ``*_service_us`` pricing — the same discipline R003 enforces on clock
    attributes, extended to the call boundary spans are built through. A
    fresh nonzero literal flowing into a span duration would let a trace
    report time the complexity model never priced.
    """

    rule_id = "R007"
    name = "span-clock-discipline"
    description = ("*_us keyword arguments in src/repro/obs/ must come "
                   "from clock values or *_service_us pricing")

    def check(self, tree: ast.Module, src: str) -> Iterator[Finding]:
        if not _in_parts(self.path, "obs"):
            return
        for node in ast.walk(tree):
            if not isinstance(node, ast.Call):
                continue
            for kw in node.keywords:
                if (kw.arg is not None and kw.arg.endswith("_us")
                        and not R003ClockDiscipline._billed(kw.value)):
                    yield self.finding(
                        kw.value,
                        f"span/metric field {kw.arg}= fed from a value "
                        f"with no clock reference or *_service_us pricing "
                        f"(trace time must mirror billed clocks)")
