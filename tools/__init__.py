"""Repo tooling: reprolint (contract checker) and check_links."""
